"""Benchmarks mirroring the paper's tables/figures.

Each function returns a list of (name, us_per_call, derived) rows;
benchmarks/run.py prints them as CSV. Simulated-fabric times use the
BGQ-calibrated constants (repro.core.fabric — fit to the paper's measured
aggregates); kernel benches measure real wall time on this host.
"""
from __future__ import annotations

import random
import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _mk_fabric(n_hosts, n_files=736, per_file=577 * 2**20 // 736):
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    blob = np.zeros(per_file, np.uint8)
    paths = []
    for i in range(n_files):
        fab.fs.files[f"d/{i}.bin"] = blob      # shared buffer (RAM frugal)
        paths.append(f"d/{i}.bin")
    return fab, paths


def fig10_staging_write() -> List[Row]:
    """Staging+Write aggregate bandwidth vs node count (Fig. 10)."""
    from repro.core.staging import stage_collective
    rows = []
    for hosts in (256, 512, 1024, 2048, 4096, 8192):
        fab, paths = _mk_fabric(hosts)
        rep, _ = stage_collective(fab, paths)
        rows.append((f"fig10_staging_write_n{hosts}",
                     rep.total_time * 1e6,
                     f"agg_GBps={rep.delivered_bandwidth/1e9:.1f}"))
    return rows


def fig11_end_to_end() -> List[Row]:
    """End-to-end input: hook vs naive at 8192 nodes (Fig. 11 + §VI-B).
    Paper: 46.75 s vs 210 s (4.7x); 101 vs 21 GB/s."""
    from repro.core.fabric import BGQ
    from repro.core.staging import stage_collective
    fab, paths = _mk_fabric(8192)
    rep, _ = stage_collective(fab, paths)
    read_phase = 577 * 2**20 / BGQ.local_read_bw
    hook_total = rep.total_time + read_phase
    naive_total = 8192 * 577 * 2**20 / BGQ.fs_rand_bw
    agg = 8192 * 577 * 2**20
    return [
        ("fig11_hook_end_to_end", hook_total * 1e6,
         f"agg_GBps={agg/hook_total/1e9:.1f}"),
        ("fig11_naive_end_to_end", naive_total * 1e6,
         f"agg_GBps={agg/naive_total/1e9:.1f}"),
        ("fig11_input_time_ratio", 0.0,
         f"ratio={naive_total/hook_total:.2f}x_paper=4.7x"),
    ]


def _makespan_rows(tag, n_tasks, dur_range, workers_list, seed=1) -> List[Row]:
    from repro.core.fabric import Fabric
    from repro.core.manytask import ManyTaskEngine, Task
    r = random.Random(seed)
    durations = [r.uniform(*dur_range) for _ in range(n_tasks)]
    rows = []
    for w in workers_list:
        fab = Fabric(n_hosts=max(1, w // 16), ranks_per_host=16)
        eng = ManyTaskEngine(fab, n_workers=w)
        stats = eng.run([Task(task_id=i, duration=d)
                         for i, d in enumerate(durations)])
        eff = stats.cpu_seconds() / (stats.makespan * w)
        rows.append((f"{tag}_w{w}", stats.makespan * 1e6,
                     f"efficiency={eff*100:.0f}%"))
    return rows


def fig12_ff_stage1_makespan() -> List[Row]:
    """FF-HEDM stage 1: 720 jobs, 5-160 s each (Fig. 12)."""
    return _makespan_rows("fig12_ff1", 720, (5, 160), (40, 80, 160, 320))


def fig13_ff_stage2_makespan() -> List[Row]:
    """FF-HEDM stage 2: 4,109 jobs, 5-25 s each (Fig. 13)."""
    return _makespan_rows("fig13_ff2", 4109, (5, 25), (40, 80, 160, 320))


def nf_reduction() -> List[Row]:
    """§VI-A: NF data reduction — measured kernel throughput on this host,
    scaled to the paper's 736-image workload."""
    import jax.numpy as jnp
    from repro.hedm.pipeline import simulate_detector_frames
    from repro.kernels.ops import hedm_reduce
    frames, dark = simulate_detector_frames(8, size=256, n_spots=8)
    fj, dj = jnp.asarray(frames), jnp.asarray(dark)
    hedm_reduce(fj, dj)                      # compile
    t0 = time.perf_counter()
    masks, counts = hedm_reduce(fj, dj)
    masks.block_until_ready()
    dt = time.perf_counter() - t0
    per_frame = dt / 8
    return [("nf_reduction_per_frame", per_frame * 1e6,
             f"px_per_s={256*256/per_frame:.2e}"),
            ("nf_reduction_736_frames_est", per_frame * 736 * 1e6,
             "paper=106s_on_320_cores")]


def metadata_contention() -> List[Row]:
    """§IV: leader-glob + broadcast vs per-rank glob storm."""
    from repro.core.fabric import BGQ, Fabric
    from repro.core.iohook import naive_per_rank_globs, resolve_manifest
    fab = Fabric(n_hosts=512, ranks_per_host=16, constants=BGQ)
    for i in range(64):
        fab.fs.put(f"s/f{i}.py", np.ones(64, np.uint8))
    _, t_leader = resolve_manifest(fab, ["s/*.py"], 0.0)
    fab2 = Fabric(n_hosts=512, ranks_per_host=16, constants=BGQ)
    for i in range(64):
        fab2.fs.put(f"s/f{i}.py", np.ones(64, np.uint8))
    t_naive = naive_per_rank_globs(fab2, ["s/*.py"])
    return [("metadata_leader_glob", t_leader * 1e6, ""),
            ("metadata_per_rank_glob", t_naive * 1e6,
             f"ratio={t_naive/max(t_leader,1e-12):.0f}x")]


def checkpoint_staged_restore() -> List[Row]:
    """Staging applied to checkpoint restore: collective (1x read + ICI
    all-gather) vs naive (P x reads), modeled on the TPU fabric."""
    from repro.core.fabric import TPU_POD
    c = TPU_POD
    ckpt = 16 * 2 ** 30                      # 16 GiB checkpoint
    rows = []
    for hosts in (64, 256):
        t_coll = (c.coll_latency_base + c.coll_latency_log * np.log2(hosts)
                  + ckpt / c.fs_seq_bw
                  + (ckpt / hosts) / c.link_bw * (hosts - 1)
                  + ckpt / c.local_bw)
        t_naive = hosts * ckpt / c.fs_rand_bw + ckpt / c.local_bw
        rows.append((f"ckpt_restore_collective_n{hosts}", t_coll * 1e6,
                     f"GBps={ckpt/t_coll/1e9:.1f}"))
        rows.append((f"ckpt_restore_naive_n{hosts}", t_naive * 1e6,
                     f"GBps={ckpt/t_naive/1e9:.1f}"))
    return rows


def kernel_microbench() -> List[Row]:
    """Wall-time micro-benchmarks of the Pallas kernels (interpret mode on
    CPU: correctness-path timing, NOT TPU perf — the roofline report covers
    the TPU-side projections)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import flash_attention
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 512, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    v = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    flash_attention(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    flash_attention(q, k, v).block_until_ready()
    dt = time.perf_counter() - t0
    flops = 4 * 512 * 512 * 8 * 64
    return [("flash_attention_512_interp", dt * 1e6,
             f"gflops={flops/dt/1e9:.2f}")]


ALL = [fig10_staging_write, fig11_end_to_end, fig12_ff_stage1_makespan,
       fig13_ff_stage2_makespan, nf_reduction, metadata_contention,
       checkpoint_staged_restore, kernel_microbench]

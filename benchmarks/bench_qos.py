"""QoS-vs-FIFO scheduling benchmark under facility-scale tenant load.

N tenants submit heavy-tailed dataset requests to one
`repro.core.datasvc.StagingService` at P=8192 hosts through the
event-driven `repro.core.qos.QoSScheduler`: open-loop Poisson arrivals
(three intensities — below, near, and past the service's saturation
point) with Pareto-distributed lease hold times and a size-skewed
dataset popularity, plus a closed-loop variant where each tenant thinks
(exponential) and resubmits on completion. Both policies replay the SAME
arrival schedule, so the comparison isolates the scheduling discipline:

  * ``fifo`` — strict arrival order, head-of-line blocking, serial
    cheapest-first eviction (the baseline a lease-queue service gives);
  * ``qos`` — priority + aging + fair-share backfill, preemptive
    lowest-priority-first eviction.

Reported per (intensity, policy): P50/P99 session latency (submit ->
data usable), goodput (delivered bytes per simulated second), shared-FS
queueing (``SharedFilesystem.wait_time``), preemptions. Asserted on
every full run: all requests complete under both policies, and QoS
strictly beats FIFO on P99 latency at every overloaded intensity.

``--quick`` recomputes the small deterministic anchor (P=64) and asserts
exact equality with the recorded ``BENCH_qos.json`` — the CI parity
smoke (the P=8192 sweep is not rerun).

Run directly:  PYTHONPATH=src python -m benchmarks.bench_qos [--quick]
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_qos.json")

# driven through the event-driven scheduler over the staging service
API_PATH = "qos scheduler (event timeline)"

N_HOSTS = 8192
N_TENANTS = 8
N_REQUESTS = 160
# heavy-tailed dataset sizes (one file each keeps the P=8192 Python cost
# bounded); popularity is size-skewed — small datasets are hot, the big
# scans rare, so a big stage parking at the queue head is exactly the
# FIFO failure mode
DATASETS = (("d0", 1 << 20), ("d1", 1 << 20), ("d2", 1 << 20),
            ("d3", 1 << 20), ("d4", 4 << 20), ("d5", 4 << 20),
            ("d6", 16 << 20), ("d7", 16 << 20))
POPULARITY = (0.22, 0.22, 0.16, 0.16, 0.1, 0.1, 0.02, 0.02)
BUDGET_BYTES = 20 << 20                 # under half the 44 MiB corpus: the
#                                         two 16 MiB scans mutually exclude
HOLD_SCALE = 0.25                       # Pareto hold-time scale (s)
HOLD_ALPHA = 1.5                        # heavy tail (infinite variance)
HOLD_CAP = 8.0
# open-loop arrival intensities (requests per simulated second):
# below, near, and well past saturation of the leased-memory pipeline
INTENSITIES = (5.0, 15.0, 40.0)
OVERLOADED = (15.0, 40.0)               # where the QoS-beats-FIFO bar applies
SEED = 2026

QUICK_N_HOSTS = 64
QUICK_N_REQUESTS = 160
QUICK_INTENSITIES = (15.0, 40.0)


def _service(n_hosts: int):
    from repro.core.datasvc import StagingService
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    rng = np.random.default_rng(0)
    svc = StagingService(fab, budget_bytes=BUDGET_BYTES)
    for name, size in DATASETS:
        path = f"{name}/scan.bin"
        fab.fs.put(path, rng.integers(0, 255, size, dtype=np.uint8))
        svc.register(name, paths=[path])
    return fab, svc


def _policy(name: str):
    from repro.core.qos import FIFO, QoSPolicy
    return FIFO if name == "fifo" else QoSPolicy(aging_rate=2.0)


def _open_loop(n_hosts: int, policy_name: str, rate: float,
               n_requests: int, tracer=None) -> dict:
    """One open-loop run: Poisson(rate) arrivals, Pareto holds, the same
    schedule for every policy (fixed seed). ``tracer`` optionally
    attaches a `repro.core.telemetry.Tracer` — the returned accounting
    must be identical either way (telemetry records, never charges)."""
    from repro.core.qos import QoSScheduler
    fab, svc = _service(n_hosts)
    if tracer is not None:
        fab.attach_tracer(tracer)
    sched = QoSScheduler(svc, policy=_policy(policy_name))
    rng = np.random.default_rng(SEED)
    names = [n for n, _ in DATASETS]
    t = 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        tenant = int(rng.integers(0, N_TENANTS))
        d = int(rng.choice(len(names), p=POPULARITY))
        hold = min(float((rng.pareto(HOLD_ALPHA) + 1) * HOLD_SCALE),
                   HOLD_CAP)
        sched.submit(f"t{tenant}", names[d], t, priority=tenant % 3,
                     hold=hold)
    sched.run()
    assert not sched.pending and len(sched.completed) == n_requests
    s = sched.summary()
    s.update({"policy": policy_name, "rate_hz": rate,
              "stages": svc.stats.stages, "hits": svc.stats.hits,
              "coalesced": svc.stats.coalesced,
              "evictions": svc.stats.evictions,
              "fs_wait_s": fab.fs.wait_time,
              "fs_busy_s": fab.fs.busy_time})
    return s


def _closed_loop(n_hosts: int, policy_name: str, think_s: float,
                 per_tenant: int) -> dict:
    """Closed-loop variant: each tenant holds one request in flight,
    thinking (exponential) between completion and the next submit."""
    from repro.core.qos import QoSScheduler
    fab, svc = _service(n_hosts)
    sched = QoSScheduler(svc, policy=_policy(policy_name))
    rng = np.random.default_rng(SEED + 1)
    names = [n for n, _ in DATASETS]
    left = {f"t{i}": per_tenant - 1 for i in range(N_TENANTS)}

    def next_request(tenant: str, t: float):
        d = int(rng.choice(len(names), p=POPULARITY))
        hold = min(float((rng.pareto(HOLD_ALPHA) + 1) * HOLD_SCALE),
                   HOLD_CAP)

        def resubmit(req):
            if left[tenant] > 0:
                left[tenant] -= 1
                next_request(tenant,
                             req.t_release + float(rng.exponential(think_s)))

        sched.submit(tenant, names[d], t,
                     priority=int(tenant[1:]) % 3, hold=hold,
                     on_complete=resubmit)

    for i in range(N_TENANTS):
        next_request(f"t{i}", float(rng.exponential(think_s)))
    sched.run()
    expect = N_TENANTS * per_tenant
    assert len(sched.completed) == expect, \
        f"closed loop completed {len(sched.completed)} != {expect}"
    s = sched.summary()
    s.update({"policy": policy_name, "think_s": think_s,
              "stages": svc.stats.stages, "hits": svc.stats.hits,
              "evictions": svc.stats.evictions})
    return s


def _sweep(n_hosts: int, intensities, n_requests: int) -> List[dict]:
    out = []
    for rate in intensities:
        for policy in ("fifo", "qos"):
            out.append(_open_loop(n_hosts, policy, rate, n_requests))
    return out


def _assert_qos_wins(sweep: List[dict], overloaded) -> None:
    by = {(r["rate_hz"], r["policy"]): r for r in sweep}
    for rate in overloaded:
        fifo, qos = by[(rate, "fifo")], by[(rate, "qos")]
        assert qos["p99_latency"] < fifo["p99_latency"], (
            f"qos P99 {qos['p99_latency']:.3f}s did not beat fifo "
            f"{fifo['p99_latency']:.3f}s at rate {rate}/s")


def bench_open_loop() -> List[dict]:
    sweep = _sweep(N_HOSTS, INTENSITIES, N_REQUESTS)
    _assert_qos_wins(sweep, OVERLOADED)
    return sweep


def bench_closed_loop() -> List[dict]:
    return [_closed_loop(N_HOSTS, policy, think_s=0.2, per_tenant=8)
            for policy in ("fifo", "qos")]


def quick_anchor() -> List[dict]:
    """Small deterministic configuration for the CI parity smoke: same
    workload shape at P=64 (every number is simulated, so exact JSON
    equality is the bar)."""
    sweep = _sweep(QUICK_N_HOSTS, QUICK_INTENSITIES, QUICK_N_REQUESTS)
    _assert_qos_wins(sweep, QUICK_INTENSITIES)
    return sweep


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    report = {
        "config": {
            "calibration": BGQ.name,
            "api_path": API_PATH,
            "n_hosts": N_HOSTS, "n_tenants": N_TENANTS,
            "n_requests": N_REQUESTS,
            "datasets": {n: s for n, s in DATASETS},
            "budget_bytes": BUDGET_BYTES,
            "hold_pareto": {"alpha": HOLD_ALPHA, "scale_s": HOLD_SCALE,
                            "cap_s": HOLD_CAP},
            "intensities_hz": list(INTENSITIES),
            "seed": SEED,
        },
        "open_loop": bench_open_loop(),
        "closed_loop": bench_closed_loop(),
        "quick_anchor": quick_anchor(),
    }
    # telemetry: replay one anchor configuration traced — the summary
    # must be IDENTICAL to the untraced anchor run (simulation
    # neutrality), and the registry snapshot (qos.latency_s histogram,
    # park counters, svc/fs/net series) rides along in the report
    from repro.core.telemetry import Tracer
    tracer = Tracer()
    traced = _open_loop(QUICK_N_HOSTS, "qos", QUICK_INTENSITIES[0],
                        QUICK_N_REQUESTS, tracer=tracer)
    anchor = next(r for r in report["quick_anchor"]
                  if r["policy"] == "qos"
                  and r["rate_hz"] == QUICK_INTENSITIES[0])
    assert traced == anchor, \
        "tracing changed the qos simulated accounting"
    report["metrics"] = tracer.metrics.snapshot()
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def quick_check() -> dict:
    """CI smoke: recompute the P=64 anchor (deterministic simulated
    accounting) and assert exact equality with the recorded baseline —
    including that QoS still beats FIFO on P99 at both anchor
    intensities. The P=8192 sweep is trusted to the recorded file."""
    with open(JSON_PATH) as f:
        base = json.load(f)
    recorded = base.get("quick_anchor")
    assert recorded is not None, (
        f"{JSON_PATH} is missing 'quick_anchor'; rerun the full benchmark "
        f"(python -m benchmarks.bench_qos)")
    fresh = quick_anchor()
    assert fresh == recorded, (
        f"qos scheduling accounting drifted at P={QUICK_N_HOSTS}:\n"
        f"  recorded: {recorded}\n  computed: {fresh}\n"
        f"re-baseline with the full benchmark if this is intentional")
    return {"baseline": os.path.basename(JSON_PATH),
            "checked": [{"name": f"anchor_{r['policy']}_r{r['rate_hz']:g}",
                         "parity": True} for r in fresh]}


def rows(report=None, quick: bool = False) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run.
    us_per_call carries simulated P99 latency in µs."""
    if quick:
        result = quick_check()
        return [(f"bench_quick_{c['name']}", 0.0, "sim_parity=True")
                for c in result["checked"]]
    if report is None:
        report = run_benchmarks()
    out: List[Row] = []
    for r in report["open_loop"]:
        out.append((
            f"bench_qos_{r['policy']}_r{r['rate_hz']:g}",
            r["p99_latency"] * 1e6,
            f"p50={r['p50_latency']:.3f}s"
            f"_goodput={r['goodput_bytes_per_s'] / 1e6:.1f}MBps"))
    for r in report["closed_loop"]:
        out.append((
            f"bench_qos_closed_{r['policy']}",
            r["p99_latency"] * 1e6,
            f"p50={r['p50_latency']:.3f}s_completed={r['completed']}"))
    return out


def main() -> None:
    if "--quick" in sys.argv[1:]:
        result = quick_check()
        for c in result["checked"]:
            print(f"{c['name']}: simulated accounting matches "
                  f"{result['baseline']}")
        print(f"quick parity OK ({len(result['checked'])} checks)")
        return
    report = run_benchmarks()
    by_rate = {}
    for r in report["open_loop"]:
        by_rate.setdefault(r["rate_hz"], {})[r["policy"]] = r
    for rate, pair in sorted(by_rate.items()):
        f, q = pair["fifo"], pair["qos"]
        print(f"open-loop {rate:g}/s: fifo P50/P99 "
              f"{f['p50_latency']:.3f}/{f['p99_latency']:.3f}s, qos "
              f"{q['p50_latency']:.3f}/{q['p99_latency']:.3f}s "
              f"({f['p99_latency'] / q['p99_latency']:.1f}x better P99), "
              f"goodput {f['goodput_bytes_per_s'] / 1e6:.1f} -> "
              f"{q['goodput_bytes_per_s'] / 1e6:.1f} MB/s")
    for r in report["closed_loop"]:
        print(f"closed-loop {r['policy']}: P50/P99 "
              f"{r['p50_latency']:.3f}/{r['p99_latency']:.3f}s over "
              f"{r['completed']} requests")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()

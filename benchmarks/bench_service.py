"""Multi-tenant staging-service benchmark: coalescing, eviction, write-back.

One interactive HEDM scenario at P=1024 hosts: 4 concurrent analysis
sessions lease 3 scans through the `repro.core.datasvc.StagingService`
under a node-memory budget that fits only 2 scans — forcing cost-aware
eviction, transparent re-staging, and queued admissions — and flush their
reduced results back to the shared FS. Asserted on every run:

  * request coalescing stages each dataset EXACTLY ONCE per residency
    (acquires = stages + coalesced + hits, per dataset and in aggregate);
  * every session's packed output is byte-exact vs reducing the scan
    directly, eviction/re-staging notwithstanding, and so is the
    write-back content landed on the shared FS;
  * the collective ``stage_out`` write-back (disjoint 1/P stripe writes
    via ``write_gather``) beats the naive every-host-writes baseline by a
    measured simulated-time factor at P=1024.

Emits ``BENCH_service.json`` next to this file and harness CSV rows via
:func:`rows` (wired into ``benchmarks.run --service``).

Run directly:  PYTHONPATH=src python -m benchmarks.bench_service
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_service.json")

# which staging API surface this bench drives (run.py summary column):
# run_interactive_hedm routes every lease through StagingClient sessions
API_PATH = "client (service sessions)"

N_HOSTS = 1024
N_FRAMES = 16
FRAME_SIZE = 128
N_SPOTS = 6
REDUCE_S_PER_FRAME = 0.15
DATASETS = ("scanA", "scanB", "scanC")
SESSION_PLANS = (                       # 4 tenants, overlapping access order
    ("s1", ("scanA", "scanB", "scanC"), 0.0),
    ("s2", ("scanA", "scanC", "scanB"), 0.0),
    ("s3", ("scanB", "scanA", "scanC"), 0.5),
    ("s4", ("scanC", "scanB", "scanA"), 1.0),
)


def _scenario():
    from repro.hedm.pipeline import SessionScript, simulate_detector_frames
    scans, dark = {}, None
    for i, name in enumerate(DATASETS):
        frames, dark = simulate_detector_frames(N_FRAMES, size=FRAME_SIZE,
                                                n_spots=N_SPOTS, seed=i)
        scans[name] = frames
    budget = 2 * N_FRAMES * FRAME_SIZE * FRAME_SIZE * 4 + 1024  # 2 of 3 fit
    sessions = [SessionScript(n, list(ds), t_start=t,
                              reduce_s_per_frame=REDUCE_S_PER_FRAME)
                for n, ds, t in SESSION_PLANS]
    return scans, dark, sessions, budget


def bench_service() -> dict:
    from repro.core.fabric import BGQ, Fabric
    from repro.hedm.pipeline import (pack_reduced, reduce_frames,
                                     run_interactive_hedm)

    scans, dark, sessions, budget = _scenario()
    fab = Fabric(n_hosts=N_HOSTS, constants=BGQ)
    res = run_interactive_hedm(fab, scans, dark, sessions, budget)
    svc, st = res.service, res.service.stats

    # coalescing invariant: one stage per residency, per dataset
    stage_once = True
    per_dataset = {}
    for entry in svc.catalog:
        residencies = sum(1 for _, s in entry.history if s.value == "resident")
        ok = (entry.stage_count == residencies
              and entry.acquires == entry.stage_count + entry.coalesced
              + entry.hits)
        stage_once &= ok
        per_dataset[entry.name] = {
            "residencies": residencies, "stage_count": entry.stage_count,
            "acquires": entry.acquires, "coalesced": entry.coalesced,
            "hits": entry.hits, "invariant_ok": ok,
        }
    assert stage_once, f"stage-per-residency invariant broken: {per_dataset}"
    # the OBSERVABLE form of the same invariant: collective staging reads
    # each dataset exactly once per residency off the shared FS, so total
    # FS read traffic must equal sum(stage_count * nbytes) — a coalesce
    # path that secretly re-staged would show up here as extra bytes
    expect_fs = sum(e.stage_count * e.nbytes for e in svc.catalog)
    assert fab.fs.bytes_read == expect_fs, \
        (f"FS read traffic {fab.fs.bytes_read} != one read per residency "
         f"{expect_fs}: a coalesced acquire re-staged")
    assert st.coalesced > 0, "scenario exercised no request coalescing"
    assert st.evictions > 0 and st.restages > 0, \
        "scenario exercised no eviction/re-staging"

    # byte-exactness: session outputs AND landed write-back files
    refs = {n: pack_reduced(reduce_frames(np.float32(f), dark,
                                          use_kernel=False))
            for n, f in scans.items()}
    byte_exact = all(
        np.array_equal(outs[n], refs[n])
        for outs in res.outputs.values() for n in outs)
    byte_exact &= all(
        np.array_equal(fab.fs.files[p], refs[ds].view(np.uint8).ravel())
        for paths in res.result_paths.values() for ds, p in paths.items())
    assert byte_exact, "session outputs diverged from direct reduction"

    return {
        "stages": st.stages, "restages": st.restages,
        "coalesced": st.coalesced, "hits": st.hits,
        "evictions": st.evictions, "queue_waits": st.queue_waits,
        "queue_wait_s": st.queue_wait_time,
        "turnaround_s": res.turnaround,
        "stage_once_per_residency": stage_once,
        "fs_bytes_read": fab.fs.bytes_read,
        "fs_bytes_expected": expect_fs,
        "byte_exact": byte_exact,
        "per_dataset": per_dataset,
    }


def bench_writeback() -> dict:
    """Collective vs naive write-back of the sessions' result payloads at
    P=1024, on idle fabrics (pure engine comparison)."""
    from repro.core.fabric import BGQ, Fabric
    from repro.core.staging import stage_out, stage_out_naive

    rng = np.random.default_rng(0)
    # one result archive per session: a full reduced scan (the paper's
    # 8 MB frame -> ~1 MB binary, x frames), 16 MB each
    outputs = {f"results/s{i}/scan.bin":
               rng.integers(0, 255, 16 << 20, dtype=np.uint8)
               for i in range(len(SESSION_PLANS))}
    rep_c, _ = stage_out(Fabric(n_hosts=N_HOSTS, constants=BGQ), outputs)
    rep_n, _ = stage_out_naive(Fabric(n_hosts=N_HOSTS, constants=BGQ),
                               outputs)
    total = sum(b.size for b in outputs.values())
    assert rep_c.fs_write_bytes == total                  # 1x the results
    assert rep_n.fs_write_bytes == N_HOSTS * total        # P x the results
    return {
        "n_hosts": N_HOSTS, "result_bytes": total,
        "collective_s": rep_c.total_time, "naive_s": rep_n.total_time,
        "speedup": rep_n.total_time / rep_c.total_time,
    }


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    report = {
        "config": {
            "calibration": BGQ.name,
            "api_path": API_PATH,
            "n_hosts": N_HOSTS, "n_datasets": len(DATASETS),
            "n_sessions": len(SESSION_PLANS), "n_frames": N_FRAMES,
            "frame_size": FRAME_SIZE,
            "budget_bytes": _scenario()[3],
            "reduce_s_per_frame": REDUCE_S_PER_FRAME,
        },
        "service": bench_service(),
        "writeback": bench_writeback(),
    }
    # telemetry: rerun the collective write-back traced — an identical
    # total proves tracing is simulation-neutral; the registry snapshot
    # rides along in the report
    from repro.core.fabric import BGQ as _BGQ, Fabric
    from repro.core.staging import stage_out
    from repro.core.telemetry import Tracer
    rng = np.random.default_rng(0)
    outputs = {f"results/s{i}/scan.bin":
               rng.integers(0, 255, 16 << 20, dtype=np.uint8)
               for i in range(len(SESSION_PLANS))}
    fab = Fabric(n_hosts=N_HOSTS, constants=_BGQ)
    tracer = fab.attach_tracer(Tracer())
    rep_t, _ = stage_out(fab, outputs)
    assert rep_t.total_time == report["writeback"]["collective_s"], \
        "tracing changed the simulated accounting"
    report["metrics"] = tracer.metrics.snapshot()
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def rows(report=None) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run.
    us_per_call carries simulated seconds in µs."""
    if report is None:
        report = run_benchmarks()
    svc, wb = report["service"], report["writeback"]
    return [
        ("bench_service_turnaround", svc["turnaround_s"] * 1e6,
         f"stages={svc['stages']}_coalesced={svc['coalesced']}"
         f"_evictions={svc['evictions']}"),
        ("bench_service_stage_out_P1024", wb["collective_s"] * 1e6,
         f"speedup_vs_naive={wb['speedup']:.1f}x"),
    ]


def main() -> None:
    report = run_benchmarks()
    svc, wb = report["service"], report["writeback"]
    print(f"service: {svc['stages']} stages ({svc['restages']} re-stages), "
          f"{svc['coalesced']} coalesced, {svc['evictions']} evictions, "
          f"{svc['queue_waits']} queued admissions -> turnaround "
          f"{svc['turnaround_s']:.2f}s (byte-exact: {svc['byte_exact']}, "
          f"one stage per residency: {svc['stage_once_per_residency']})")
    print(f"write-back @P={wb['n_hosts']}: naive {wb['naive_s']:.3f}s -> "
          f"collective {wb['collective_s']:.3f}s "
          f"({wb['speedup']:.1f}x)")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()

"""Cross-facility WAN ingest: pub/sub fan-out economics + jitter sweep.

One synthetic acquisition (48 x 128x128 float32 frames) crosses the
``wan_beamline`` topology's wide-area ingest tier three ways:

  * **anchor** — the degenerate WAN stage (no jitter, no loss, credits
    never bind) against the local ``stage_stream`` engine: asserted
    byte- and time-exact per run (the regression anchor; re-checked by
    ``run.py --wan --quick`` on CI);
  * **fanout** — N subscriber campaigns tap ONE WAN stream vs N
    independent WAN pulls of the same set: frames cross the WAN once,
    so pub/sub moves 1/N of the independent-pull wire bytes (asserted
    >= 2x cheaper at N=4);
  * **jitter sweep** — seeded WAN brownouts + loss over a bounded
    credit window and DAQ buffer: flow control must finish every run
    with every frame accounted (delivered + dropped == emitted, the
    never-wedge guarantee) and replay bit-exactly per seed.

Everything is simulated seconds over real bytes. Emits
``BENCH_wan.json`` next to this file and harness CSV rows via
:func:`rows` (wired into ``benchmarks.run --wan``).

Run directly:  PYTHONPATH=src python -m benchmarks.bench_wan
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import fields
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_wan.json")

# which staging API surface this bench drives (run.py summary column)
API_PATH = "engine (stage_wan / stage_stream)"

N_HOSTS = 64
N_FRAMES = 48
FRAME_SIZE = 128
FRAME_BYTES = FRAME_SIZE * FRAME_SIZE * 4
RATE_HZ = 100.0
FAN_NS = (1, 2, 4)
JITTER_SEEDS = (0, 1, 2, 3, 4)
CREDIT_WINDOW = 6
BUFFER_FRAMES = 8
WINDOW_FRAMES = 8


def _fabric():
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=N_HOSTS, constants=BGQ)
    rng = np.random.default_rng(7)
    paths = []
    for i in range(N_FRAMES):
        p = f"scan/frame_{i:05d}.bin"
        fab.fs.put(p, rng.integers(0, 255, FRAME_BYTES, dtype=np.uint8))
        paths.append(p)
    return fab, paths


def bench_anchor() -> dict:
    """Zero-jitter/zero-loss WAN stage vs local stage_stream: exact."""
    from repro.core.streaming import stage_stream
    from repro.core.wan import stage_wan
    f1, paths = _fabric()
    f2, _ = _fabric()
    rs, ts = stage_stream(f1, paths, rate_hz=RATE_HZ)
    rw, tw = stage_wan(f2, paths, rate_hz=RATE_HZ)
    exact = ts == tw and all(
        getattr(rs, f.name) == getattr(rw, f.name)
        for f in fields(rs) if f.name != "mode")
    for h1, h2 in zip(f1.hosts, f2.hosts):
        exact = exact and set(h1.store.data) == set(h2.store.data) and all(
            np.array_equal(h1.store.data[p], h2.store.data[p])
            for p in h1.store.data)
    assert exact, "WAN default path diverged from stage_stream"
    return {
        "name": "anchor_wan_vs_stream",
        "rate_hz": RATE_HZ,
        "n_frames": N_FRAMES,
        "frame_bytes": FRAME_BYTES,
        "makespan_s": tw,
        "stream_makespan_s": ts,
        "byte_exact": True,
    }


def bench_fanout() -> List[dict]:
    """N subscribers on one stream vs N independent WAN pulls."""
    from repro.core.wan import stage_wan
    out = []
    for n in FAN_NS:
        fab, paths = _fabric()
        rep, _ = stage_wan(fab, paths, rate_hz=RATE_HZ,
                           topology="wan_beamline", subscribers=n,
                           consume_hz=50.0)
        shared = rep.tier_bytes["wan"]
        independent = 0
        t_indep = 0.0
        for _ in range(n):
            f_i, _ = _fabric()
            r_i, t_i = stage_wan(f_i, paths, rate_hz=RATE_HZ,
                                 topology="wan_beamline")
            independent += r_i.tier_bytes["wan"]
            t_indep = max(t_indep, t_i)
        ratio = independent / shared
        out.append({
            "name": f"fanout_n{n}",
            "subscribers": n,
            "pubsub_wan_bytes": shared,
            "independent_wan_bytes": independent,
            "wan_bytes_ratio": ratio,
            "pubsub_makespan_s": rep.wan.makespan,
            "independent_makespan_s": t_indep,
            "watermark_lag_s": rep.wan.stream.watermark_lag,
        })
        if n >= 2:
            assert ratio >= 2.0, (
                f"pub/sub fan-out must move >=2x fewer WAN bytes than "
                f"{n} independent pulls, got {ratio:.2f}x")
    return out


def bench_jitter_sweep() -> List[dict]:
    """Seeded brownouts + loss over bounded credits: never wedges."""
    from repro.core.wan import stage_wan

    def run(seed):
        fab, paths = _fabric()
        return stage_wan(fab, paths, rate_hz=RATE_HZ,
                         topology="wan_beamline",
                         window_bytes=WINDOW_FRAMES * FRAME_BYTES,
                         credit_window=CREDIT_WINDOW,
                         buffer_frames=BUFFER_FRAMES,
                         subscribers=2, consume_hz=40.0,
                         loss_rate=0.15, loss_seed=seed,
                         jitter_seed=seed, jitter_windows=8,
                         jitter_factors=(0.2, 0.6))

    out = []
    for seed in JITTER_SEEDS:
        rep, t = run(seed)
        rep2, t2 = run(seed)
        wan = rep.wan
        assert t == t2 and wan.makespan == rep2.wan.makespan, \
            f"seed {seed} did not replay bit-exactly"
        assert wan.frames_delivered + wan.frames_dropped == wan.n_frames, \
            f"seed {seed} lost frames unaccounted"
        out.append({
            "name": f"jitter_seed{seed}",
            "seed": seed,
            "makespan_s": wan.makespan,
            "frames_delivered": wan.frames_delivered,
            "frames_dropped": wan.frames_dropped,
            "retransmits": wan.retransmits,
            "wan_bytes": wan.wan_bytes,
            "credit_stall_s": wan.credit_stall_time,
            "buffer_peak": wan.buffer_peak,
            "replay_exact": True,
        })
    return out


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    report = {
        "config": {
            "calibration": BGQ.name,
            "api_path": API_PATH,
            "topology": "wan_beamline",
            "n_hosts": N_HOSTS, "n_frames": N_FRAMES,
            "frame_bytes": FRAME_BYTES, "rate_hz": RATE_HZ,
            "credit_window": CREDIT_WINDOW,
            "buffer_frames": BUFFER_FRAMES,
            "window_frames": WINDOW_FRAMES,
        },
        "anchor": bench_anchor(),
        "fanout": bench_fanout(),
        "jitter_sweep": bench_jitter_sweep(),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def quick_check() -> None:
    """CI smoke: the anchor must hold and fan-out must stay >=2x at N=4
    (no JSON rewrite)."""
    bench_anchor()
    from repro.core.wan import stage_wan
    fab, paths = _fabric()
    rep, _ = stage_wan(fab, paths, rate_hz=RATE_HZ,
                       topology="wan_beamline", subscribers=4,
                       consume_hz=50.0)
    shared = rep.tier_bytes["wan"]
    assert shared == N_FRAMES * FRAME_BYTES, "frames must cross WAN once"
    print("bench_wan quick: anchor byte-exact, "
          f"fanout n=4 moves {4 * shared / shared:.0f}x fewer WAN bytes "
          "than independent pulls")


def rows(report=None, quick=False) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run.
    us_per_call carries the simulated WAN makespan in µs. ``quick``
    asserts the anchor + fan-out invariants only (no JSON rewrite)."""
    if quick:
        anchor = bench_anchor()
        quick_check()
        return [("bench_wan_anchor_quick", anchor["makespan_s"] * 1e6,
                 "byte_exact_vs_stream=True")]
    if report is None:
        report = run_benchmarks()
    out: List[Row] = [(
        "bench_wan_anchor", report["anchor"]["makespan_s"] * 1e6,
        "byte_exact_vs_stream=True")]
    for r in report["fanout"]:
        out.append((f"bench_wan_{r['name']}",
                    r["pubsub_makespan_s"] * 1e6,
                    f"wan_bytes_ratio={r['wan_bytes_ratio']:.2f}x"))
    for r in report["jitter_sweep"]:
        out.append((f"bench_wan_{r['name']}",
                    r["makespan_s"] * 1e6,
                    f"dropped={r['frames_dropped']}"
                    f"/retx={r['retransmits']}"))
    return out


def main() -> None:
    report = run_benchmarks()
    a = report["anchor"]
    print(f"{a['name']}: makespan {a['makespan_s']:.3f}s (byte- and "
          f"time-exact vs stage_stream)")
    for r in report["fanout"]:
        print(f"{r['name']}: pub/sub moves {r['pubsub_wan_bytes']} B over "
              f"the WAN vs {r['independent_wan_bytes']} B independent "
              f"({r['wan_bytes_ratio']:.2f}x cheaper)")
    for r in report["jitter_sweep"]:
        print(f"{r['name']}: makespan {r['makespan_s']:.3f}s, "
              f"{r['frames_delivered']} delivered / "
              f"{r['frames_dropped']} dropped, "
              f"{r['retransmits']} retransmits, "
              f"credit stall {r['credit_stall_s']:.3f}s (replay exact)")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    if "--quick" in sys.argv:
        quick_check()
    else:
        main()

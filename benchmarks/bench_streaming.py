"""End-to-end turnaround: batch stage-then-process vs overlapped streaming.

One synthetic HEDM acquisition (48 x 128x128 float32 frames) is run both
ways at several acquisition rates:

  * **batch** — the paper's workflow: detector -> shared FS, wait for the
    scan to close, ``stage_collective`` the whole dataset to every node,
    then one-shot stage-1 reduction (``run_batch_hedm``);
  * **stream** — frames are pushed straight into node memory as produced
    (scatter + ring broadcast, bounded sliding window with backpressure)
    and reduced per window while acquisition is still in flight
    (``run_online_hedm``).

Both paths run the REAL reduction over the node-local replicas and are
asserted bit-identical per rate; the charged stage-1 cost is a declared
``REDUCE_S_PER_FRAME`` simulated seconds per frame (the ManyTaskEngine
duration idiom), so the turnaround comparison is deterministic. Acquisition
and delivery times come from the fabric model (simulated seconds).

Emits ``BENCH_streaming.json`` next to this file and harness CSV rows via
:func:`rows` (wired into ``benchmarks.run --streaming``).

Run directly:  PYTHONPATH=src python -m benchmarks.bench_streaming
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_streaming.json")

# which staging API surface this bench drives (run.py summary column):
# both HEDM runners wire their staging through the unified client
API_PATH = "client (hedm runners)"

N_HOSTS = 64
N_FRAMES = 48
FRAME_SIZE = 128
WINDOW = 8                   # frames per online reduce batch
CACHE_FRAMES = 16            # per-node sliding-window budget (frames)
REDUCE_S_PER_FRAME = 0.15    # declared stage-1 cost (simulated s/frame)
RATES_HZ = (2.0, 20.0, 200.0)   # acquisition-bound ... compute-bound


def _fabric():
    from repro.core.fabric import BGQ, Fabric
    return Fabric(n_hosts=N_HOSTS, constants=BGQ)


def bench_turnaround() -> List[dict]:
    from repro.hedm.pipeline import (run_batch_hedm, run_online_hedm,
                                     simulate_detector_frames)
    frames, dark = simulate_detector_frames(N_FRAMES, size=FRAME_SIZE,
                                            n_spots=8, seed=2)
    out = []
    for rate in RATES_HZ:
        batch, t_batch, stage_rep = run_batch_hedm(
            _fabric(), frames, dark, rate_hz=rate, use_kernel=False,
            reduce_time_per_frame=REDUCE_S_PER_FRAME)
        online = run_online_hedm(
            _fabric(), frames, dark, rate_hz=rate, window=WINDOW,
            use_kernel=False, cache_frames=CACHE_FRAMES,
            reduce_time_per_frame=REDUCE_S_PER_FRAME)

        byte_exact = len(online.reduced) == len(batch) and all(
            a.frame_id == b.frame_id and a.n_spots == b.n_spots
            and np.array_equal(a.peaks, b.peaks)
            for a, b in zip(online.reduced, batch))
        assert byte_exact, f"stream/batch HEDM mismatch at {rate} Hz"

        t_acq = N_FRAMES / rate
        out.append({
            "name": f"turnaround_rate{rate:g}hz",
            "rate_hz": rate,
            "n_frames": N_FRAMES,
            "frame_bytes": FRAME_SIZE * FRAME_SIZE * 4,
            "acquisition_s": t_acq,
            "batch_turnaround_s": t_batch,
            "batch_stage_s": stage_rep.total_time,
            "stream_turnaround_s": online.turnaround,
            "stream_first_window_s": online.window_done[0],
            "stream_stall_s": online.stream.stall_time,
            "stream_evictions": online.stream.evictions,
            "stream_peak_resident_bytes": online.stream.peak_resident_bytes,
            "speedup": t_batch / online.turnaround,
            "byte_exact": byte_exact,
        })
    return out


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    report = {
        "config": {
            "calibration": BGQ.name,
            "api_path": API_PATH,
            "n_hosts": N_HOSTS, "n_frames": N_FRAMES,
            "frame_size": FRAME_SIZE, "window_frames": WINDOW,
            "cache_frames": CACHE_FRAMES,
            "reduce_s_per_frame": REDUCE_S_PER_FRAME,
        },
        "turnaround": bench_turnaround(),
    }
    # telemetry: rerun the slowest-rate online pipeline traced — an
    # identical turnaround proves tracing is simulation-neutral; the
    # registry snapshot (stream frame latency, stalls, residency) rides
    # along in the report
    from repro.core.telemetry import Tracer
    from repro.hedm.pipeline import run_online_hedm, simulate_detector_frames
    frames, dark = simulate_detector_frames(N_FRAMES, size=FRAME_SIZE,
                                            n_spots=8, seed=2)
    fab = _fabric()
    tracer = fab.attach_tracer(Tracer())
    online = run_online_hedm(fab, frames, dark, rate_hz=RATES_HZ[0],
                             window=WINDOW, use_kernel=False,
                             cache_frames=CACHE_FRAMES,
                             reduce_time_per_frame=REDUCE_S_PER_FRAME)
    assert online.turnaround == report["turnaround"][0][
        "stream_turnaround_s"], "tracing changed the simulated accounting"
    report["metrics"] = tracer.metrics.snapshot()
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def rows(report=None) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run.
    us_per_call carries the simulated streaming turnaround in µs."""
    if report is None:
        report = run_benchmarks()
    out: List[Row] = []
    for r in report["turnaround"]:
        out.append((f"bench_stream_{r['name']}",
                    r["stream_turnaround_s"] * 1e6,
                    f"speedup_vs_batch={r['speedup']:.2f}x"))
    return out


def main() -> None:
    report = run_benchmarks()
    for r in report["turnaround"]:
        print(f"{r['name']}: acq {r['acquisition_s']:.1f}s | batch "
              f"{r['batch_turnaround_s']:.2f}s -> stream "
              f"{r['stream_turnaround_s']:.2f}s  ({r['speedup']:.2f}x, "
              f"first window at {r['stream_first_window_s']:.2f}s, "
              f"stall {r['stream_stall_s']:.2f}s, "
              f"{r['stream_evictions']} evictions, byte-exact)")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()

"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (harness contract). The roofline
table (EXPERIMENTS.md §Roofline) is produced separately by
``python -m benchmarks.roofline`` from the dry-run artifacts, and the
staging/labeling hot-path microbenchmark by ``--staging`` (also emits
``BENCH_staging.json``; standalone: ``python -m benchmarks.bench_staging``).
``--streaming`` runs the batch-vs-streaming turnaround comparison (emits
``BENCH_streaming.json``; standalone: ``python -m benchmarks.bench_streaming``).
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    print("name,us_per_call,derived")
    if "--staging" in sys.argv[1:]:
        from benchmarks import bench_staging
        for name, us, derived in bench_staging.rows():
            print(f"{name},{us:.1f},{derived}")
        return
    if "--streaming" in sys.argv[1:]:
        from benchmarks import bench_streaming
        for name, us, derived in bench_streaming.rows():
            print(f"{name},{us:.1f},{derived}")
        return
    from benchmarks import paper_figures
    for fn in paper_figures.ALL:
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

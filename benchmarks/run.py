"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV on stdout (harness contract). The
roofline table (EXPERIMENTS.md §Roofline) is produced separately by
``python -m benchmarks.roofline`` from the dry-run artifacts; the
staging/labeling hot-path microbenchmark by ``--staging``, the
batch-vs-streaming turnaround comparison by ``--streaming``, and the
multi-tenant staging-service scenario by ``--service``, the
fault-tolerance repair-vs-restage comparison by ``--faults``, the
QoS-vs-FIFO concurrent-session scheduling sweep by ``--qos``, and the
cross-facility WAN ingest fan-out/jitter sweep by ``--wan`` (each also
emits its ``BENCH_*.json``; standalone: ``python -m benchmarks.bench_<name>``).
``--wan --quick`` asserts the zero-jitter/zero-loss WAN path byte- and
time-exact vs the local streaming engine plus the pub/sub fan-out
invariant — the CI WAN-parity smoke.
``--staging --quick`` skips every wall-clock comparison and instead
asserts the SIMULATED FLAT-topology accounting (plus the topology-plan
costs) match the recorded ``BENCH_staging.json`` baseline exactly — the
CI accounting-parity smoke. ``--faults --quick`` does the same for the
fault model against ``BENCH_faults.json`` (including the zero-fault
bit-exactness anchor against the staging baseline), and ``--qos --quick``
for the scheduler against the small deterministic anchor recorded in
``BENCH_qos.json``.

``--staging --quick --trace`` additionally records a full telemetry
timeline during the parity runs (`repro.core.telemetry`) and exports the
largest-P Chrome trace to ``benchmarks/TRACE_staging.json`` (load it at
https://ui.perfetto.dev) — parity holding tracer-ON is the CI
telemetry-neutrality smoke.

Every invocation ends with a consolidated summary of ALL ``BENCH_*.json``
files present (on stderr, so the stdout CSV contract is preserved),
including the fabric calibration each was measured under, which staging
API surface drove it (``legacy shim`` vs ``client``), and — for result
files carrying a telemetry ``metrics`` block — a P50/P99 column from the
shared registry histograms (QoS latency, stage totals, per-collective
durations; see docs/observability.md).
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# the repo root, so `python benchmarks/run.py` resolves the benchmarks
# package exactly like `python -m benchmarks.run`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def _headline(name: str, report: dict) -> str:
    """One-line takeaway per known BENCH_*.json schema (generic fallback)."""
    try:
        if name == "BENCH_staging.json":
            s = report["staging"][-1]          # largest host count
            lab = report["labeling"]
            head = (f"{s['name']} {s['speedup']:.1f}x vs legacy; "
                    f"labeling {lab['speedup']:.0f}x")
            hp = report.get("hook_paths")
            if hp:
                head += (f"; shim==client accounting: "
                         f"{hp['simulated_accounting_match']}")
            topo = report.get("topology")
            if topo:
                t = topo[-1]                   # largest host count
                head += (f"; {t['name']} hier "
                         f"{t['speedup_hier_vs_flat']:.1f}x vs flat ring")
            return head
        if name == "BENCH_streaming.json":
            rs = report["turnaround"]
            lo = min(r["speedup"] for r in rs)
            hi = max(r["speedup"] for r in rs)
            return (f"stream vs batch {lo:.2f}-{hi:.2f}x over "
                    f"{len(rs)} rates, byte-exact")
        if name == "BENCH_faults.json":
            rr = report["repair_vs_restage"][-1]     # largest host count
            a = report["zero_fault_anchor"]
            return (f"repair {rr['speedup']:.0f}x vs re-stage "
                    f"@P{rr['name'].rsplit('P', 1)[1]}; zero-fault "
                    f"bit-exact: {a['bit_exact']}")
        if name == "BENCH_service.json":
            svc, wb = report["service"], report["writeback"]
            return (f"{svc['stages']} stages/{svc['coalesced']} coalesced/"
                    f"{svc['evictions']} evictions; stage_out "
                    f"{wb['speedup']:.1f}x vs naive @P{wb['n_hosts']}")
        if name == "BENCH_wan.json":
            fan = report["fanout"][-1]               # largest subscriber count
            sweep = report["jitter_sweep"]
            dropped = sum(r["frames_dropped"] for r in sweep)
            return (f"pub/sub {fan['wan_bytes_ratio']:.0f}x fewer WAN bytes "
                    f"@N={fan['subscribers']}; anchor byte-exact: "
                    f"{report['anchor']['byte_exact']}; jitter sweep "
                    f"{len(sweep)} seeds replay-exact ({dropped} drops "
                    f"accounted)")
        if name == "BENCH_compression.json":
            w = report["wan_headline"]
            hi = max(r["speedup"] for r in report["hierarchical"])
            crossed = sum(1 for r in report["crossover"] if r["compressed"])
            return (f"WAN wire {w['wan_bytes_ratio']:.1f}x smaller "
                    f"(frame-lossless); hierarchical up to {hi:.1f}x; "
                    f"crossover {crossed}/{len(report['crossover'])} cells "
                    f"compressed; identity anchor exact: "
                    f"{report['anchor']['byte_exact']}")
        if name == "BENCH_qos.json":
            by = {(r["rate_hz"], r["policy"]): r for r in report["open_loop"]}
            rate = max(r for r, _ in by)
            f, q = by[(rate, "fifo")], by[(rate, "qos")]
            return (f"qos P99 {f['p99_latency'] / q['p99_latency']:.1f}x "
                    f"better than fifo @{rate:g}req/s "
                    f"(P{report['config']['n_hosts']}), goodput "
                    f"{f['goodput_bytes_per_s'] / 1e6:.0f}->"
                    f"{q['goodput_bytes_per_s'] / 1e6:.0f}MB/s")
    except Exception:
        pass          # a malformed result file must never kill the summary
    try:
        return ", ".join(sorted(report)[:4])
    except Exception:
        return "-"


def _calibration(report: dict) -> str:
    try:
        return (report.get("calibration")
                or report.get("config", {}).get("calibration", "-"))
    except Exception:
        return "-"


def _api_path(report: dict) -> str:
    """Which staging API surface the bench drove: the unified client, the
    legacy run_io_hook shim, or '-' for pre-redesign result files."""
    try:
        return (report.get("api_path")
                or report.get("config", {}).get("api_path", "-"))
    except Exception:
        return "-"


# which registry histogram a result file's P50/P99 column quotes, in
# preference order (the first one present with observations wins)
_SUMMARY_HISTOGRAMS = ("qos.latency_s", "stage.total_s",
                       "stream.frame_latency_s", "collective.duration_s")


def _percentiles(report: dict) -> str:
    """``hist=P50/P99`` from the report's telemetry ``metrics`` block
    (the shared `repro.core.telemetry.MetricsRegistry` snapshot), or
    '-' for result files recorded before the telemetry PR."""
    try:
        hists = report.get("metrics", {}).get("histograms", {})
        for name in _SUMMARY_HISTOGRAMS:
            h = hists.get(name)
            if h and h.get("count") and h.get("p50") is not None:
                return f"{name}={h['p50']:.3f}/{h['p99']:.3f}s"
    except Exception:
        pass
    return "-"


def print_summary(out=sys.stderr) -> None:
    """Consolidated table across every BENCH_*.json in this directory."""
    paths = sorted(glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json")))
    if not paths:
        return
    rows = []
    for path in paths:
        try:
            with open(path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError):
            rows.append((os.path.basename(path), "-", "-", "-",
                         "unreadable"))
            continue
        rows.append((os.path.basename(path), _calibration(report),
                     _api_path(report), _percentiles(report),
                     _headline(os.path.basename(path), report)))
    w_name = max(len(r[0]) for r in rows)
    w_cal = max(max(len(r[1]) for r in rows), len("calibration"))
    w_api = max(max(len(r[2]) for r in rows), len("api_path"))
    w_pct = max(max(len(r[3]) for r in rows), len("p50/p99"))
    print(f"\n== BENCH summary ({len(rows)} result files) ==", file=out)
    print(f"{'file':<{w_name}}  {'calibration':<{w_cal}}  "
          f"{'api_path':<{w_api}}  {'p50/p99':<{w_pct}}  headline", file=out)
    for name, cal, api, pct, head in rows:
        print(f"{name:<{w_name}}  {cal:<{w_cal}}  {api:<{w_api}}  "
              f"{pct:<{w_pct}}  {head}", file=out)


def main() -> None:
    print("name,us_per_call,derived")
    try:
        if "--staging" in sys.argv[1:]:
            from benchmarks import bench_staging
            quick = "--quick" in sys.argv[1:]
            trace = "--trace" in sys.argv[1:]
            print(f"[bench_staging] api_path={bench_staging.API_PATH}"
                  f"{' quick=sim-parity-only' if quick else ''}"
                  f"{' trace=on' if trace else ''}",
                  file=sys.stderr)
            for name, us, derived in bench_staging.rows(quick=quick,
                                                        trace=trace):
                print(f"{name},{us:.1f},{derived}")
            if trace and quick:
                print(f"[bench_staging] wrote {bench_staging.TRACE_PATH} "
                      f"(load at https://ui.perfetto.dev)", file=sys.stderr)
        elif "--streaming" in sys.argv[1:]:
            from benchmarks import bench_streaming
            print(f"[bench_streaming] api_path={bench_streaming.API_PATH}",
                  file=sys.stderr)
            for name, us, derived in bench_streaming.rows():
                print(f"{name},{us:.1f},{derived}")
        elif "--service" in sys.argv[1:]:
            from benchmarks import bench_service
            print(f"[bench_service] api_path={bench_service.API_PATH}",
                  file=sys.stderr)
            for name, us, derived in bench_service.rows():
                print(f"{name},{us:.1f},{derived}")
        elif "--faults" in sys.argv[1:]:
            from benchmarks import bench_faults
            quick = "--quick" in sys.argv[1:]
            print(f"[bench_faults] api_path={bench_faults.API_PATH}"
                  f"{' quick=sim-parity-only' if quick else ''}",
                  file=sys.stderr)
            for name, us, derived in bench_faults.rows(quick=quick):
                print(f"{name},{us:.1f},{derived}")
        elif "--qos" in sys.argv[1:]:
            from benchmarks import bench_qos
            quick = "--quick" in sys.argv[1:]
            print(f"[bench_qos] api_path={bench_qos.API_PATH}"
                  f"{' quick=sim-parity-only' if quick else ''}",
                  file=sys.stderr)
            for name, us, derived in bench_qos.rows(quick=quick):
                print(f"{name},{us:.1f},{derived}")
        elif "--wan" in sys.argv[1:]:
            from benchmarks import bench_wan
            quick = "--quick" in sys.argv[1:]
            print(f"[bench_wan] api_path={bench_wan.API_PATH}"
                  f"{' quick=anchor-parity-only' if quick else ''}",
                  file=sys.stderr)
            for name, us, derived in bench_wan.rows(quick=quick):
                print(f"{name},{us:.1f},{derived}")
        elif "--compression" in sys.argv[1:]:
            from benchmarks import bench_compression
            quick = "--quick" in sys.argv[1:]
            print(f"[bench_compression] "
                  f"api_path={bench_compression.API_PATH}"
                  f"{' quick=anchor-parity-only' if quick else ''}",
                  file=sys.stderr)
            for name, us, derived in bench_compression.rows(quick=quick):
                print(f"{name},{us:.1f},{derived}")
        else:
            from benchmarks import paper_figures
            for fn in paper_figures.ALL:
                for name, us, derived in fn():
                    print(f"{name},{us:.1f},{derived}")
    finally:
        print_summary()


if __name__ == "__main__":
    main()

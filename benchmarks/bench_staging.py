"""Microbenchmark: zero-copy staging engine + vectorized stage-1 labeling.

Measures REAL wall-clock of the simulator hot paths against faithful
re-implementations of the seed code paths:

  * ``stage_collective`` at P in {64, 256, 1024} hosts vs the legacy
    per-stripe-read + np.concatenate + per-host-write engine,
  * stage-1 connected-component labeling over a 64-frame 256x256 stack:
    vectorized run-based two-pass labeler vs the pure-Python pixel loop
    (legacy timed on a subset and extrapolated linearly when slow —
    reported as such in the JSON).

Byte-exactness of the staged replicas against the source FS is asserted on
every configuration. The "new" side drives the unified client API
(`repro.core.api.StagingClient`), and a dedicated ``hook_paths`` check
runs one identical staging job through BOTH surfaces — the legacy
``run_io_hook`` deprecation shim and ``client.stage`` — asserting
identical simulated accounting, so a shim regression shows up here.

Beyond wall clock, every staging row records its SIMULATED accounting
(``sim`` block) under the FLAT topology, and a ``topology`` section
compares the flat pipelined-ring broadcast against the planner's
hierarchical/auto plans on the BGQ 5D-torus machine at P up to 8192 —
asserting the hierarchical plan wins at P >= 4096, with per-tier bytes
reported. ``--quick`` (via ``benchmarks.run --staging --quick``)
recomputes only the simulated numbers and asserts they match the
recorded ``BENCH_staging.json`` baseline exactly — the CI accounting-
parity smoke (no wall-clock comparisons, runs in seconds).

Emits ``BENCH_staging.json`` next to this file and returns harness CSV
rows via :func:`rows` (wired into ``benchmarks.run``).

Run directly:  PYTHONPATH=src python -m benchmarks.bench_staging [--quick]
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_staging.json")
TRACE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "TRACE_staging.json")

# which staging API surface this bench drives (run.py summary column)
API_PATH = "client"

HOST_COUNTS = (64, 256, 1024)
STAGE_FILES = 4
STAGE_FILE_BYTES = 32 << 20          # 4 x 32 MiB dataset per config
LABEL_FRAMES = 64
LABEL_SIZE = 256
LEGACY_LABEL_BUDGET_S = 10.0         # time legacy on a subset if slower
TOPOLOGY_HOSTS = (1024, 4096, 8192)  # planner comparison (pure cost model)
TOPOLOGY_NBYTES = 32 << 20           # one replica broadcast per plan


# --------------------------------------------------------------------------
# legacy (seed) implementations — the "before" side of the comparison
# --------------------------------------------------------------------------

def _legacy_stage_collective(fabric, paths):
    """The seed engine: P per-stripe fs.read calls per file, np.concatenate
    replica assembly (a real dataset-sized copy), per-host write loop."""
    import math
    from repro.core.staging import _stripes
    P_ = fabric.n_hosts
    c = fabric.constants
    coll_overhead = c.coll_latency_base + c.coll_latency_log * max(
        0.0, math.log2(max(P_, 2)))
    t_read_done = 0.0
    for path in paths:
        size = fabric.fs.size(path)
        t_file = 0.0
        for off, sz in _stripes(size, P_):
            _, t_done = fabric.fs.read(path, off, sz, 0.0, coordinated=True)
            t_file = max(t_file, t_done)
        t_read_done = max(t_read_done, t_file) + coll_overhead
    total = sum(fabric.fs.size(p) for p in paths)
    stripe_bytes = max(1, (total + P_ - 1) // P_)
    import warnings
    with warnings.catch_warnings():
        # the seed path IS the deprecated alias — that is the point here
        warnings.simplefilter("ignore", DeprecationWarning)
        fabric.net.ring_allgather_time(stripe_bytes, P_)
    for path in paths:
        size = fabric.fs.size(path)
        blob = np.concatenate([fabric.fs.files[path][off:off + sz]
                               for off, sz in _stripes(size, P_)]) \
            if P_ > 1 else fabric.fs.files[path]
        for host in fabric.hosts:
            host.store.write(path, blob, 0.0)


def _make_fabric(n_hosts):
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    rng = np.random.default_rng(0)
    blob = rng.integers(0, 255, STAGE_FILE_BYTES, dtype=np.uint8)
    paths = []
    for i in range(STAGE_FILES):
        fab.fs.put(f"d/{i}.bin", blob)
        paths.append(f"d/{i}.bin")
    return fab, paths


def _check_replicas(fabric, paths):
    probe = [0, len(fabric.hosts) // 2, len(fabric.hosts) - 1]
    for h in probe:
        store = fabric.hosts[h].store
        for p in paths:
            assert np.array_equal(store.data[p], fabric.fs.files[p]), \
                f"replica mismatch host={h} path={p}"


def _sim_dict(rep) -> dict:
    """A client Report reduced to its SIMULATED accounting — the ONE
    shape both the recorded baseline and quick_check compare (strict
    dict equality, so full-run and quick-run must share this builder)."""
    r = rep.reports[0]
    return {
        "total_time": rep.total_time, "stage_time": r.stage_time,
        "comm_time": r.comm_time, "write_time": r.write_time,
        "fs_bytes": r.fs_bytes, "net_bytes": r.net_bytes,
        "tier_bytes": dict(r.tier_bytes),
    }


def _stage_client_run(hosts: int, trace: bool = False):
    """One FLAT-topology client staging run (optionally traced); returns
    ``(sim_dict, client)``. Replicas are byte-checked as a side effect."""
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    fab, paths = _make_fabric(hosts)
    spec = StagingSpec([BroadcastEntry(tuple(paths), pin=False)])
    client = StagingClient(fab, trace=trace)
    rep = client.stage(spec, CollectiveConfig(), resolve=False)
    _check_replicas(fab, paths)
    return _sim_dict(rep), client


def _stage_sim_accounting(hosts: int, trace: bool = False) -> dict:
    """One FLAT-topology client staging run, reduced to its SIMULATED
    accounting (deterministic — the quick-mode parity anchor). With
    ``trace`` the run records a full span timeline; the Chrome trace is
    validated and, at the largest P, exported to ``TRACE_staging.json``
    — parity asserted by the caller then PROVES telemetry never touches
    the simulated arithmetic."""
    sim, client = _stage_client_run(hosts, trace=trace)
    if trace:
        from repro.core.telemetry import (to_chrome_trace,
                                          validate_chrome_trace)
        validate_chrome_trace(to_chrome_trace(client.tracer))
        if hosts == max(HOST_COUNTS):
            client.write_trace(TRACE_PATH)
    return sim


def bench_stage_collective() -> List[dict]:
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    out = []
    for hosts in HOST_COUNTS:
        fab_new, paths = _make_fabric(hosts)
        spec = StagingSpec([BroadcastEntry(tuple(paths), pin=False)])
        client = StagingClient(fab_new)
        t0 = time.perf_counter()
        rep = client.stage(spec, CollectiveConfig(), resolve=False)
        t_new = time.perf_counter() - t0
        _check_replicas(fab_new, paths)
        sim = _sim_dict(rep)

        fab_old, paths = _make_fabric(hosts)
        t0 = time.perf_counter()
        _legacy_stage_collective(fab_old, paths)
        t_old = time.perf_counter() - t0
        _check_replicas(fab_old, paths)

        out.append({
            "name": f"stage_collective_P{hosts}",
            "dataset_bytes": STAGE_FILES * STAGE_FILE_BYTES,
            "legacy_s": t_old, "zero_copy_s": t_new,
            "speedup": t_old / t_new, "byte_exact": True,
            "sim": sim,
        })
    return out


def bench_topology_plans() -> List[dict]:
    """Flat pipelined ring vs the collective planner on the BGQ 5D-torus
    topology: one 32 MiB replica broadcast per plan, P up to 8192. Pure
    simulated cost model (`repro.core.collectives`) — no wall clock, no
    real bytes. Asserts the hierarchical plan (and a fortiori the auto
    selection) beats the flat ring at P >= 4096; per-tier wire bytes are
    recorded for every plan."""
    from repro.core.collectives import CollectivePlanner
    from repro.core.fabric import BGQ
    from repro.core.topology import BGQ_TORUS
    planner = CollectivePlanner(BGQ_TORUS, BGQ)
    out = []
    for hosts in TOPOLOGY_HOSTS:
        flat = planner.plan_broadcast(TOPOLOGY_NBYTES, hosts,
                                      algorithm="pipelined_ring")
        hier = planner.plan_broadcast(TOPOLOGY_NBYTES, hosts,
                                      algorithm="hierarchical")
        auto = planner.plan_broadcast(TOPOLOGY_NBYTES, hosts)
        if hosts >= 4096:
            assert hier.time < flat.time, \
                f"hierarchical lost to the flat ring at P={hosts}"
            assert auto.time <= hier.time
        out.append({
            "name": f"broadcast_P{hosts}",
            "topology": BGQ_TORUS.name, "nbytes": TOPOLOGY_NBYTES,
            "flat_ring_s": flat.time,
            "hierarchical_s": hier.time,
            "auto_s": auto.time, "auto_algorithm": auto.algorithm,
            "speedup_hier_vs_flat": flat.time / hier.time,
            "flat_tier_bytes": flat.tier_bytes,
            "hier_tier_bytes": hier.tier_bytes,
            "auto_tier_bytes": auto.tier_bytes,
        })
    return out


def bench_labeling() -> dict:
    import jax.numpy as jnp
    from repro.hedm.pipeline import (label_components,
                                     simulate_detector_frames,
                                     _union_find_label)
    from repro.kernels.hedm_reduce_ref import reference
    frames, dark = simulate_detector_frames(LABEL_FRAMES, size=LABEL_SIZE,
                                            n_spots=12, seed=1)
    masks = np.asarray(reference(jnp.asarray(frames), jnp.asarray(dark),
                                 threshold=200.0)[0]) > 0

    t0 = time.perf_counter()
    new_results = [label_components(m) for m in masks]
    t_new = time.perf_counter() - t0

    # legacy pixel loop: time one frame, run as many as the budget allows,
    # extrapolate linearly (it is O(pixels) per frame, same every frame)
    t0 = time.perf_counter()
    old0 = _union_find_label(masks[0])
    per_frame = time.perf_counter() - t0
    n_legacy = max(1, min(LABEL_FRAMES,
                          int(LEGACY_LABEL_BUDGET_S / max(per_frame, 1e-9))))
    t0 = time.perf_counter()
    old_results = [_union_find_label(m) for m in masks[:n_legacy]]
    t_old_measured = time.perf_counter() - t0
    t_old = t_old_measured * (LABEL_FRAMES / n_legacy)

    for (l_new, n_new), (l_old, n_old) in zip(new_results, old_results):
        assert n_new == n_old and np.array_equal(l_new, l_old), \
            "labeler mismatch vs legacy union-find"
    _ = old0
    return {
        "name": f"labeling_{LABEL_FRAMES}x{LABEL_SIZE}x{LABEL_SIZE}",
        "vectorized_s": t_new,
        "legacy_s": t_old,
        "legacy_frames_measured": n_legacy,
        "legacy_extrapolated": n_legacy < LABEL_FRAMES,
        "speedup": t_old / t_new,
        "labels_match_legacy": True,
    }


def bench_hook_paths() -> dict:
    """One identical hook-style staging job through the legacy
    ``run_io_hook`` shim AND ``StagingClient.stage`` (twin fabrics):
    asserts identical simulated accounting and byte-exact replicas, and
    times both surfaces — a shim regression (semantic or wall-clock)
    shows up here."""
    import warnings

    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    from repro.core.iohook import run_io_hook

    spec = StagingSpec([BroadcastEntry(("d/*.bin",))])
    fab_shim, paths = _make_fabric(64)
    t0 = time.perf_counter()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = run_io_hook(fab_shim, spec, mode="collective")
    t_shim = time.perf_counter() - t0
    _check_replicas(fab_shim, paths)

    fab_cli, paths = _make_fabric(64)
    t0 = time.perf_counter()
    new = StagingClient(fab_cli).stage(spec, CollectiveConfig())
    t_cli = time.perf_counter() - t0
    _check_replicas(fab_cli, paths)

    match = (old.total_time == new.total_time
             and old.metadata_time == new.metadata_time
             and old.resolved_files == new.resolved_files
             and [r.total_time for r in old.reports]
             == [r.total_time for r in new.reports])
    assert match, "legacy run_io_hook shim diverged from StagingClient"
    return {
        "n_hosts": 64, "dataset_bytes": STAGE_FILES * STAGE_FILE_BYTES,
        "legacy_shim_s": t_shim, "client_s": t_cli,
        "simulated_accounting_match": match, "byte_exact": True,
    }


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    staging = bench_stage_collective()
    labeling = bench_labeling()
    hook_paths = bench_hook_paths()
    topology = bench_topology_plans()
    # telemetry: rerun the largest config traced — identical sim
    # accounting proves tracing is simulation-neutral, the registry
    # snapshot rides along in the report, and the Chrome trace artifact
    # lands next to the baseline
    sim_traced, traced = _stage_client_run(max(HOST_COUNTS), trace=True)
    assert sim_traced == staging[-1]["sim"], \
        "tracing changed the simulated accounting"
    traced.write_trace(TRACE_PATH)
    report = {"calibration": BGQ.name, "api_path": API_PATH,
              "staging": staging, "labeling": labeling,
              "hook_paths": hook_paths, "topology": topology,
              "metrics": traced.tracer.metrics.snapshot()}
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def quick_check(trace: bool = False) -> dict:
    """CI smoke: recompute ONLY the simulated numbers (FLAT staging
    accounting + topology plans — seconds of wall time, no legacy
    engines, no labeling) and assert exact equality with the recorded
    ``BENCH_staging.json`` baseline. Simulated accounting is
    deterministic, so any drift is a real cost-model change — rerun the
    full benchmark to re-baseline when it is intentional. With ``trace``
    the same runs record a full telemetry timeline (exported at the
    largest P) — parity holding tracer-ON is the telemetry-neutrality
    smoke."""
    with open(JSON_PATH) as f:
        base = json.load(f)
    checked = []
    for s in base["staging"]:
        hosts = int(s["name"].rsplit("P", 1)[1])
        recorded = s.get("sim")
        assert recorded is not None, (
            f"{JSON_PATH} predates the sim-accounting baseline; rerun the "
            f"full benchmark (python -m benchmarks.bench_staging)")
        sim = _stage_sim_accounting(hosts, trace=trace)
        assert sim == recorded, (
            f"FLAT-topology simulated accounting drifted at P={hosts}:\n"
            f"  recorded: {recorded}\n  computed: {sim}\n"
            f"re-baseline with the full benchmark if this is intentional")
        checked.append({"name": s["name"], "parity": True})
    fresh = {t["name"]: t for t in bench_topology_plans()}
    for t in base.get("topology", []):
        now = fresh[t["name"]]
        for key in ("flat_ring_s", "hierarchical_s", "auto_s",
                    "auto_algorithm"):
            assert now[key] == t[key], (
                f"topology plan {t['name']} drifted on {key}: "
                f"recorded {t[key]!r}, computed {now[key]!r}")
        checked.append({"name": f"topology_{t['name']}", "parity": True})
    return {"baseline": os.path.basename(JSON_PATH), "checked": checked}


def rows(report=None, quick: bool = False, trace: bool = False) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run.
    ``quick`` runs :func:`quick_check` against the recorded baseline
    instead of the full wall-clock benchmark; ``trace`` records a
    telemetry timeline during the quick runs (exported to
    ``TRACE_staging.json``)."""
    if quick:
        result = quick_check(trace=trace)
        return [(f"bench_quick_{c['name']}", 0.0, "sim_parity=True")
                for c in result["checked"]]
    if report is None:
        report = run_benchmarks()
    out: List[Row] = []
    for s in report["staging"]:
        out.append((f"bench_{s['name']}_zero_copy", s["zero_copy_s"] * 1e6,
                    f"speedup_vs_legacy={s['speedup']:.1f}x"))
    lab = report["labeling"]
    out.append((f"bench_{lab['name']}_vectorized", lab["vectorized_s"] * 1e6,
                f"speedup_vs_legacy={lab['speedup']:.1f}x"))
    hp = report["hook_paths"]
    out.append(("bench_hook_shim_vs_client", hp["legacy_shim_s"] * 1e6,
                f"accounting_match={hp['simulated_accounting_match']}"))
    for t in report["topology"]:
        out.append((f"bench_topology_{t['name']}",
                    t["hierarchical_s"] * 1e6,
                    f"hier_vs_flat_ring={t['speedup_hier_vs_flat']:.1f}x"))
    return out


def main() -> None:
    if "--quick" in sys.argv[1:]:
        result = quick_check(trace="--trace" in sys.argv[1:])
        for c in result["checked"]:
            print(f"{c['name']}: simulated accounting matches "
                  f"{result['baseline']}")
        print(f"quick parity OK ({len(result['checked'])} checks)")
        return
    report = run_benchmarks()
    for s in report["staging"]:
        print(f"{s['name']}: legacy {s['legacy_s']:.3f}s -> zero-copy "
              f"{s['zero_copy_s']:.3f}s  ({s['speedup']:.1f}x, byte-exact)")
    lab = report["labeling"]
    extra = (f" (legacy extrapolated from {lab['legacy_frames_measured']} "
             f"frames)" if lab["legacy_extrapolated"] else "")
    print(f"{lab['name']}: legacy {lab['legacy_s']:.2f}s -> vectorized "
          f"{lab['vectorized_s']:.3f}s  ({lab['speedup']:.0f}x){extra}")
    hp = report["hook_paths"]
    print(f"hook paths @P64: legacy shim {hp['legacy_shim_s']:.3f}s wall, "
          f"client {hp['client_s']:.3f}s wall, simulated accounting match: "
          f"{hp['simulated_accounting_match']}")
    for t in report["topology"]:
        print(f"topology {t['name']} ({t['topology']}): flat ring "
              f"{t['flat_ring_s']:.3f}s -> hierarchical "
              f"{t['hierarchical_s']:.3f}s "
              f"({t['speedup_hier_vs_flat']:.1f}x; auto picks "
              f"{t['auto_algorithm']} at {t['auto_s']:.3f}s)")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()

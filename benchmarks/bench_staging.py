"""Microbenchmark: zero-copy staging engine + vectorized stage-1 labeling.

Measures REAL wall-clock of the simulator hot paths against faithful
re-implementations of the seed code paths:

  * ``stage_collective`` at P in {64, 256, 1024} hosts vs the legacy
    per-stripe-read + np.concatenate + per-host-write engine,
  * stage-1 connected-component labeling over a 64-frame 256x256 stack:
    vectorized run-based two-pass labeler vs the pure-Python pixel loop
    (legacy timed on a subset and extrapolated linearly when slow —
    reported as such in the JSON).

Byte-exactness of the staged replicas against the source FS is asserted on
every configuration. The "new" side drives the unified client API
(`repro.core.api.StagingClient`), and a dedicated ``hook_paths`` check
runs one identical staging job through BOTH surfaces — the legacy
``run_io_hook`` deprecation shim and ``client.stage`` — asserting
identical simulated accounting, so a shim regression shows up here.
Emits ``BENCH_staging.json`` next to this file and returns harness CSV
rows via :func:`rows` (wired into ``benchmarks.run``).

Run directly:  PYTHONPATH=src python -m benchmarks.bench_staging
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_staging.json")

# which staging API surface this bench drives (run.py summary column)
API_PATH = "client"

HOST_COUNTS = (64, 256, 1024)
STAGE_FILES = 4
STAGE_FILE_BYTES = 32 << 20          # 4 x 32 MiB dataset per config
LABEL_FRAMES = 64
LABEL_SIZE = 256
LEGACY_LABEL_BUDGET_S = 10.0         # time legacy on a subset if slower


# --------------------------------------------------------------------------
# legacy (seed) implementations — the "before" side of the comparison
# --------------------------------------------------------------------------

def _legacy_stage_collective(fabric, paths):
    """The seed engine: P per-stripe fs.read calls per file, np.concatenate
    replica assembly (a real dataset-sized copy), per-host write loop."""
    import math
    from repro.core.staging import _stripes
    P_ = fabric.n_hosts
    c = fabric.constants
    coll_overhead = c.coll_latency_base + c.coll_latency_log * max(
        0.0, math.log2(max(P_, 2)))
    t_read_done = 0.0
    for path in paths:
        size = fabric.fs.size(path)
        t_file = 0.0
        for off, sz in _stripes(size, P_):
            _, t_done = fabric.fs.read(path, off, sz, 0.0, coordinated=True)
            t_file = max(t_file, t_done)
        t_read_done = max(t_read_done, t_file) + coll_overhead
    total = sum(fabric.fs.size(p) for p in paths)
    stripe_bytes = max(1, (total + P_ - 1) // P_)
    fabric.net.ring_allgather_time(stripe_bytes, P_)
    for path in paths:
        size = fabric.fs.size(path)
        blob = np.concatenate([fabric.fs.files[path][off:off + sz]
                               for off, sz in _stripes(size, P_)]) \
            if P_ > 1 else fabric.fs.files[path]
        for host in fabric.hosts:
            host.store.write(path, blob, 0.0)


def _make_fabric(n_hosts):
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    rng = np.random.default_rng(0)
    blob = rng.integers(0, 255, STAGE_FILE_BYTES, dtype=np.uint8)
    paths = []
    for i in range(STAGE_FILES):
        fab.fs.put(f"d/{i}.bin", blob)
        paths.append(f"d/{i}.bin")
    return fab, paths


def _check_replicas(fabric, paths):
    probe = [0, len(fabric.hosts) // 2, len(fabric.hosts) - 1]
    for h in probe:
        store = fabric.hosts[h].store
        for p in paths:
            assert np.array_equal(store.data[p], fabric.fs.files[p]), \
                f"replica mismatch host={h} path={p}"


def bench_stage_collective() -> List[dict]:
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    out = []
    for hosts in HOST_COUNTS:
        fab_new, paths = _make_fabric(hosts)
        spec = StagingSpec([BroadcastEntry(tuple(paths), pin=False)])
        client = StagingClient(fab_new)
        t0 = time.perf_counter()
        client.stage(spec, CollectiveConfig(), resolve=False)
        t_new = time.perf_counter() - t0
        _check_replicas(fab_new, paths)

        fab_old, paths = _make_fabric(hosts)
        t0 = time.perf_counter()
        _legacy_stage_collective(fab_old, paths)
        t_old = time.perf_counter() - t0
        _check_replicas(fab_old, paths)

        out.append({
            "name": f"stage_collective_P{hosts}",
            "dataset_bytes": STAGE_FILES * STAGE_FILE_BYTES,
            "legacy_s": t_old, "zero_copy_s": t_new,
            "speedup": t_old / t_new, "byte_exact": True,
        })
    return out


def bench_labeling() -> dict:
    import jax.numpy as jnp
    from repro.hedm.pipeline import (label_components,
                                     simulate_detector_frames,
                                     _union_find_label)
    from repro.kernels.hedm_reduce_ref import reference
    frames, dark = simulate_detector_frames(LABEL_FRAMES, size=LABEL_SIZE,
                                            n_spots=12, seed=1)
    masks = np.asarray(reference(jnp.asarray(frames), jnp.asarray(dark),
                                 threshold=200.0)[0]) > 0

    t0 = time.perf_counter()
    new_results = [label_components(m) for m in masks]
    t_new = time.perf_counter() - t0

    # legacy pixel loop: time one frame, run as many as the budget allows,
    # extrapolate linearly (it is O(pixels) per frame, same every frame)
    t0 = time.perf_counter()
    old0 = _union_find_label(masks[0])
    per_frame = time.perf_counter() - t0
    n_legacy = max(1, min(LABEL_FRAMES,
                          int(LEGACY_LABEL_BUDGET_S / max(per_frame, 1e-9))))
    t0 = time.perf_counter()
    old_results = [_union_find_label(m) for m in masks[:n_legacy]]
    t_old_measured = time.perf_counter() - t0
    t_old = t_old_measured * (LABEL_FRAMES / n_legacy)

    for (l_new, n_new), (l_old, n_old) in zip(new_results, old_results):
        assert n_new == n_old and np.array_equal(l_new, l_old), \
            "labeler mismatch vs legacy union-find"
    _ = old0
    return {
        "name": f"labeling_{LABEL_FRAMES}x{LABEL_SIZE}x{LABEL_SIZE}",
        "vectorized_s": t_new,
        "legacy_s": t_old,
        "legacy_frames_measured": n_legacy,
        "legacy_extrapolated": n_legacy < LABEL_FRAMES,
        "speedup": t_old / t_new,
        "labels_match_legacy": True,
    }


def bench_hook_paths() -> dict:
    """One identical hook-style staging job through the legacy
    ``run_io_hook`` shim AND ``StagingClient.stage`` (twin fabrics):
    asserts identical simulated accounting and byte-exact replicas, and
    times both surfaces — a shim regression (semantic or wall-clock)
    shows up here."""
    import warnings

    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    from repro.core.iohook import run_io_hook

    spec = StagingSpec([BroadcastEntry(("d/*.bin",))])
    fab_shim, paths = _make_fabric(64)
    t0 = time.perf_counter()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = run_io_hook(fab_shim, spec, mode="collective")
    t_shim = time.perf_counter() - t0
    _check_replicas(fab_shim, paths)

    fab_cli, paths = _make_fabric(64)
    t0 = time.perf_counter()
    new = StagingClient(fab_cli).stage(spec, CollectiveConfig())
    t_cli = time.perf_counter() - t0
    _check_replicas(fab_cli, paths)

    match = (old.total_time == new.total_time
             and old.metadata_time == new.metadata_time
             and old.resolved_files == new.resolved_files
             and [r.total_time for r in old.reports]
             == [r.total_time for r in new.reports])
    assert match, "legacy run_io_hook shim diverged from StagingClient"
    return {
        "n_hosts": 64, "dataset_bytes": STAGE_FILES * STAGE_FILE_BYTES,
        "legacy_shim_s": t_shim, "client_s": t_cli,
        "simulated_accounting_match": match, "byte_exact": True,
    }


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    staging = bench_stage_collective()
    labeling = bench_labeling()
    hook_paths = bench_hook_paths()
    report = {"calibration": BGQ.name, "api_path": API_PATH,
              "staging": staging, "labeling": labeling,
              "hook_paths": hook_paths}
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def rows(report=None) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run."""
    if report is None:
        report = run_benchmarks()
    out: List[Row] = []
    for s in report["staging"]:
        out.append((f"bench_{s['name']}_zero_copy", s["zero_copy_s"] * 1e6,
                    f"speedup_vs_legacy={s['speedup']:.1f}x"))
    lab = report["labeling"]
    out.append((f"bench_{lab['name']}_vectorized", lab["vectorized_s"] * 1e6,
                f"speedup_vs_legacy={lab['speedup']:.1f}x"))
    hp = report["hook_paths"]
    out.append(("bench_hook_shim_vs_client", hp["legacy_shim_s"] * 1e6,
                f"accounting_match={hp['simulated_accounting_match']}"))
    return out


def main() -> None:
    report = run_benchmarks()
    for s in report["staging"]:
        print(f"{s['name']}: legacy {s['legacy_s']:.3f}s -> zero-copy "
              f"{s['zero_copy_s']:.3f}s  ({s['speedup']:.1f}x, byte-exact)")
    lab = report["labeling"]
    extra = (f" (legacy extrapolated from {lab['legacy_frames_measured']} "
             f"frames)" if lab["legacy_extrapolated"] else "")
    print(f"{lab['name']}: legacy {lab['legacy_s']:.2f}s -> vectorized "
          f"{lab['vectorized_s']:.3f}s  ({lab['speedup']:.0f}x){extra}")
    hp = report["hook_paths"]
    print(f"hook paths @P64: legacy shim {hp['legacy_shim_s']:.3f}s wall, "
          f"client {hp['client_s']:.3f}s wall, simulated accounting match: "
          f"{hp['simulated_accounting_match']}")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()

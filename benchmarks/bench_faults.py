"""Fault-tolerance benchmark: repair vs full re-stage, degraded goodput.

All numbers here are SIMULATED accounting (the discrete-event cost model
moves real bytes but charges simulated seconds), so every row is
deterministic and the whole benchmark doubles as a parity check:

  * ``zero_fault_anchor`` — the P=1024 collective staging run with an
    (empty) ``FaultSchedule`` attached to the fabric must reproduce the
    recorded ``BENCH_staging.json`` sim accounting EXACTLY. The fault
    machinery is strictly additive; this row proves it.
  * ``repair_vs_restage`` — R=2 chained-declustered residency at
    P in {1024, 4096}: kill one host, repair with ``re_replicate``
    (moves only the lost stripes) vs bringing the dataset back through
    the shared FS. Asserts repair is cheaper in both simulated seconds
    and wire bytes at every P.
  * ``service_flow`` — a leased dataset on the staging service goes
    RESIDENT -> DEGRADED (host death) -> RESIDENT (acquire-triggered
    repair); records the service's repair accounting.
  * ``goodput`` — the same staging job healthy, with a what-if host
    death (``FaultConfig``), and with a degraded-link window: effective
    goodput (dataset bytes / simulated completion) per scenario.

Emits ``BENCH_faults.json`` next to this file and returns harness CSV
rows via :func:`rows` (wired into ``benchmarks.run --faults``).
``--quick`` recomputes every row and asserts exact equality with the
recorded baseline — the CI sim-parity smoke.

Run directly:  PYTHONPATH=src python -m benchmarks.bench_faults [--quick]
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_faults.json")
STAGING_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_staging.json")

API_PATH = "client"

ANCHOR_HOSTS = 1024                  # must exist in BENCH_staging.json
REPAIR_HOSTS = (1024, 4096)
REPLICATION = 2
STAGE_FILES = 4
STAGE_FILE_BYTES = 32 << 20          # same dataset as bench_staging


def _make_fabric(n_hosts, faults=None):
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=n_hosts, constants=BGQ, faults=faults)
    rng = np.random.default_rng(0)
    blob = rng.integers(0, 255, STAGE_FILE_BYTES, dtype=np.uint8)
    paths = []
    for i in range(STAGE_FILES):
        fab.fs.put(f"d/{i}.bin", blob)
        paths.append(f"d/{i}.bin")
    return fab, paths


def bench_zero_fault_anchor() -> dict:
    """The PR-invariant: an attached-but-empty fault schedule changes
    NOTHING. Recomputes the P=1024 FLAT staging sim accounting on a
    fabric that carries a trivial ``FaultSchedule`` and asserts it is
    bit-exact against the recorded ``BENCH_staging.json`` baseline."""
    from benchmarks.bench_staging import _check_replicas, _sim_dict
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    from repro.core.faults import FaultSchedule
    fab, paths = _make_fabric(ANCHOR_HOSTS, faults=FaultSchedule())
    spec = StagingSpec([BroadcastEntry(tuple(paths), pin=False)])
    rep = StagingClient(fab).stage(spec, CollectiveConfig(), resolve=False)
    _check_replicas(fab, paths)
    sim = _sim_dict(rep)
    with open(STAGING_JSON) as f:
        base = json.load(f)
    recorded = next(s["sim"] for s in base["staging"]
                    if s["name"] == f"stage_collective_P{ANCHOR_HOSTS}")
    assert sim == recorded, (
        f"zero-fault schedule is NOT bit-exact at P={ANCHOR_HOSTS}:\n"
        f"  recorded: {recorded}\n  computed: {sim}")
    return {"name": f"zero_fault_anchor_P{ANCHOR_HOSTS}",
            "baseline": os.path.basename(STAGING_JSON),
            "bit_exact": True, "sim": sim}


def bench_repair_vs_restage() -> List[dict]:
    """Self-healing headline: after one host death, ``re_replicate``
    (copy only the lost stripes from surviving replicas) vs a full
    re-stage of the dataset through the shared FS."""
    from repro.core.staging import re_replicate, stage_replicated
    out = []
    for hosts in REPAIR_HOSTS:
        fab, paths = _make_fabric(hosts)
        rep, t0 = stage_replicated(fab, paths, replication=REPLICATION)
        victim = hosts // 2
        fab.kill_host(victim, t0 + 1.0)
        fix, _ = re_replicate(fab, paths, rep.placement, t0=t0 + 1.0,
                              live=fab.live_ids(t0 + 1.0))
        # the alternative: bring the whole dataset back from the FS
        fab2, paths2 = _make_fabric(hosts)
        restage, _ = stage_replicated(fab2, paths2,
                                      replication=REPLICATION)
        assert fix.total_time < restage.total_time, (
            f"repair did not beat a full re-stage at P={hosts}")
        assert fix.net_bytes < restage.net_bytes
        out.append({
            "name": f"repair_vs_restage_P{hosts}",
            "replication": REPLICATION,
            "dataset_bytes": STAGE_FILES * STAGE_FILE_BYTES,
            "repair_s": fix.total_time,
            "restage_s": restage.total_time,
            "repair_bytes": fix.net_bytes,
            "restage_bytes": restage.net_bytes,
            "speedup": restage.total_time / fix.total_time,
            "repair_wins": True,
        })
    return out


def bench_service_flow() -> dict:
    """The catalog's self-healing path end to end: leased dataset, host
    death mid-residency, next acquire repairs instead of wedging."""
    from repro.core.api import ReplicatedConfig
    from repro.core.datasvc import DatasetState, StagingService
    fab, paths = _make_fabric(256)
    svc = StagingService(fab, budget_bytes=1 << 30,
                         engine=ReplicatedConfig(replication=REPLICATION))
    svc.register("scan", paths=paths, t=0.0)
    l1 = svc.acquire("alice", "scan", 0.0)
    svc.fail_host(17, l1.t_ready + 1.0)
    entry = svc.catalog["scan"]
    degraded = entry.state is DatasetState.DEGRADED
    l2 = svc.acquire("bob", "scan", l1.t_ready + 2.0)
    assert degraded and entry.state is DatasetState.RESIDENT
    assert svc.stats.repairs == 1
    assert entry.acquires == (entry.stage_count + entry.coalesced
                              + entry.hits + entry.repairs)
    return {
        "name": "service_degraded_flow_P256",
        "stage_s": svc.stats.stage_time,
        "repair_s": svc.stats.repair_time,
        "repaired_bytes": svc.stats.repaired_bytes,
        "dataset_bytes": entry.nbytes,
        "lease_survived": True,
        "repair_vs_stage": svc.stats.repair_time / svc.stats.stage_time,
    }


def bench_goodput() -> List[dict]:
    """Effective staging goodput under injected failure scenarios (all
    what-if ``FaultConfig`` overlays on twin fabrics): healthy, one host
    dead from t=0, and a half-bandwidth window on every tier."""
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                FaultConfig, StagingClient, StagingSpec)
    nbytes = STAGE_FILES * STAGE_FILE_BYTES
    scenarios = [
        ("healthy", None),
        ("one_host_dead", FaultConfig(host_deaths=((0.0, 7),))),
        ("link_degraded_50pct",
         FaultConfig(degradations=(("link", 0.0, 1e9, 0.5),))),
    ]
    out = []
    for label, faults in scenarios:
        fab, paths = _make_fabric(64)
        spec = StagingSpec([BroadcastEntry(tuple(paths), pin=False)])
        cfg = CollectiveConfig(faults=faults)
        rep = StagingClient(fab).stage(spec, cfg, resolve=False)
        out.append({
            "name": f"goodput_{label}_P64",
            "total_s": rep.total_time,
            "goodput_gbps": nbytes / rep.total_time / 1e9,
        })
    healthy = out[0]["total_s"]
    assert out[2]["total_s"] > healthy, "degraded link did not cost time"
    assert out[1]["total_s"] != healthy, "dead host left the plan untouched"
    return out


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    report = {
        "calibration": BGQ.name, "api_path": API_PATH,
        "zero_fault_anchor": bench_zero_fault_anchor(),
        "repair_vs_restage": bench_repair_vs_restage(),
        "service_flow": bench_service_flow(),
        "goodput": bench_goodput(),
    }
    # telemetry: rerun the healthy goodput scenario traced — an
    # identical total proves tracing is simulation-neutral; the registry
    # snapshot rides along (quick_check compares the sections above only)
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    fab, paths = _make_fabric(64)
    client = StagingClient(fab, trace=True)
    rep = client.stage(StagingSpec([BroadcastEntry(tuple(paths),
                                                   pin=False)]),
                       CollectiveConfig(), resolve=False)
    assert rep.total_time == report["goodput"][0]["total_s"], \
        "tracing changed the simulated accounting"
    report["metrics"] = client.tracer.metrics.snapshot()
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def quick_check() -> dict:
    """CI smoke: every row here is simulated and deterministic, so quick
    mode recomputes ALL of them and asserts exact equality with the
    recorded ``BENCH_faults.json`` (plus, transitively, the zero-fault
    anchor against ``BENCH_staging.json``). Any drift is a real
    cost-model change — re-baseline with the full benchmark when it is
    intentional."""
    with open(JSON_PATH) as f:
        base = json.load(f)
    fresh = {
        "zero_fault_anchor": bench_zero_fault_anchor(),
        "repair_vs_restage": bench_repair_vs_restage(),
        "service_flow": bench_service_flow(),
        "goodput": bench_goodput(),
    }
    checked = []
    for section, now in fresh.items():
        recorded = base.get(section)
        assert recorded is not None, (
            f"{JSON_PATH} is missing section {section!r}; rerun the full "
            f"benchmark (python -m benchmarks.bench_faults)")
        assert now == recorded, (
            f"fault-model simulated accounting drifted in {section!r}:\n"
            f"  recorded: {recorded}\n  computed: {now}\n"
            f"re-baseline with the full benchmark if this is intentional")
        checked.append({"name": section, "parity": True})
    return {"baseline": os.path.basename(JSON_PATH), "checked": checked}


def rows(report=None, quick: bool = False) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run."""
    if quick:
        result = quick_check()
        return [(f"bench_quick_{c['name']}", 0.0, "sim_parity=True")
                for c in result["checked"]]
    if report is None:
        report = run_benchmarks()
    out: List[Row] = []
    anchor = report["zero_fault_anchor"]
    out.append((f"bench_{anchor['name']}", anchor["sim"]["total_time"] * 1e6,
                f"bit_exact={anchor['bit_exact']}"))
    for r in report["repair_vs_restage"]:
        out.append((f"bench_{r['name']}", r["repair_s"] * 1e6,
                    f"repair_vs_restage={r['speedup']:.1f}x"))
    svc = report["service_flow"]
    out.append((f"bench_{svc['name']}", svc["repair_s"] * 1e6,
                f"repair_vs_stage={svc['repair_vs_stage']:.2f}x"))
    for g in report["goodput"]:
        out.append((f"bench_{g['name']}", g["total_s"] * 1e6,
                    f"goodput={g['goodput_gbps']:.2f}GB/s"))
    return out


def main() -> None:
    if "--quick" in sys.argv[1:]:
        result = quick_check()
        for c in result["checked"]:
            print(f"{c['name']}: simulated accounting matches "
                  f"{result['baseline']}")
        print(f"quick parity OK ({len(result['checked'])} checks)")
        return
    report = run_benchmarks()
    a = report["zero_fault_anchor"]
    print(f"{a['name']}: bit-exact vs {a['baseline']}: {a['bit_exact']}")
    for r in report["repair_vs_restage"]:
        print(f"{r['name']}: repair {r['repair_s']:.3f}s "
              f"({r['repair_bytes'] >> 20} MiB) vs re-stage "
              f"{r['restage_s']:.3f}s ({r['restage_bytes'] >> 20} MiB) "
              f"-> {r['speedup']:.1f}x")
    svc = report["service_flow"]
    print(f"{svc['name']}: stage {svc['stage_s']:.3f}s, repair "
          f"{svc['repair_s']:.3f}s "
          f"({svc['repaired_bytes'] >> 20} MiB moved), lease survived: "
          f"{svc['lease_survived']}")
    for g in report["goodput"]:
        print(f"{g['name']}: {g['total_s']:.3f}s "
              f"({g['goodput_gbps']:.2f} GB/s)")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()

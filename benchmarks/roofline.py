"""Roofline report: aggregates results/dryrun/*.json into the per-cell
three-term table (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

# v5e constants (duplicated from repro.launch.mesh to stay import-light)
PEAK = 197e12
HBM = 819e9
ICI = 150e9       # 3 links x 50 GB/s
DCN = 6.25e9      # 25 GB/s per 4-chip host


def model_flops(result: Dict) -> float:
    """MODEL_FLOPS per device-step: 6*N*D train, 2*N*D decode/prefill."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs.registry import get_config
    cfg = get_config(result["arch"])
    n_active = cfg.active_param_count()
    if result["kind"] == "train":
        tokens = {"train_4k": 256 * 4096}.get(result["shape"], 0)
        factor = 6.0
    elif result["kind"] == "prefill":
        tokens = 32 * 32768
        factor = 2.0
    else:
        tokens = {"decode_32k": 128, "long_500k": 1}.get(result["shape"], 1)
        factor = 2.0
    return factor * n_active * tokens / result["n_devices"]


def load_rows(pattern: str = "*.json") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            r = json.load(f)
        hc = r["hlo_cost"]
        compute = hc["flops"] / PEAK
        memory = hc["bytes"] / HBM
        coll = hc["ici_collective_bytes"] / ICI + \
            hc["dcn_collective_bytes"] / DCN
        mf = model_flops(r)
        r["table"] = {
            "cell": os.path.basename(path)[:-5],
            "compute_ms": compute * 1e3,
            "memory_ms": memory * 1e3,
            "collective_ms": coll * 1e3,
            "bottleneck": max([("compute", compute), ("memory", memory),
                               ("collective", coll)], key=lambda kv: kv[1])[0],
            "model_flops_ratio": mf / max(hc["flops"], 1.0),
            "mem_gib": r["memory"]["peak_live_bytes"] / 2 ** 30,
            "roofline_frac": compute / max(compute, memory, coll),
        }
        rows.append(r)
    return rows


def main() -> None:
    rows = load_rows()
    hdr = (f"{'cell':46s} {'compute':>9s} {'memory':>9s} {'collect':>9s} "
           f"{'bound':>10s} {'MF/HLO':>7s} {'mem GiB':>8s} {'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        t = r["table"]
        print(f"{t['cell']:46s} {t['compute_ms']:8.1f}ms {t['memory_ms']:8.1f}ms "
              f"{t['collective_ms']:8.1f}ms {t['bottleneck']:>10s} "
              f"{t['model_flops_ratio']:7.2f} {t['mem_gib']:8.2f} "
              f"{t['roofline_frac']*100:6.1f}%")


if __name__ == "__main__":
    main()
